"""Query-serving benchmark: QPS, latency percentiles, recall@k vs brute
force, for cold (compile included) and warm waves, in single-device and
sharded modes — each also through the fused Pallas descent-scoring
kernel (``*_kernel`` rows + a ``descent_scoring`` block reporting
scored-lane counts per hop vs the unfused ``beam·(kg+kr)``) — plus
online-insert throughput.

    PYTHONPATH=src python benchmarks/query_bench.py [--dataset synth]
        [--scale 0.2] [--queries 256] [--shards 2] [--out BENCH_query.json]

``--devices N`` (default: the shard count) emulates N XLA host devices —
the multi-core serving configuration, one shard per device via
shard_map; ``--devices 0`` forces the single-device vmap fallback.
``--continuous`` adds the slot-scheduler comparison: closed-loop
continuous rows plus a Poisson-arrival *open-loop* run (requests are
submitted at their arrival times, not all at once) reporting p50/p95
under load for wave vs continuous serving — the tail-latency case
continuous batching exists for — both single-device AND under the
sharded placement (the ``sharded_N_continuous`` block: per-shard slot
arrays with a release-time cross-shard merge, same Poisson protocol).
``--smoke`` shrinks the workload for CI: it still exercises build,
every serving plan, and insertion, and fails loudly (exit 1) if the
sharded mode regresses against single-device beyond the allowed
margins (with ``--continuous``: if streaming admission loses results,
recall parity with waves, or — sharded × continuous — bitwise
closed-loop equality with the sharded wave).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

# The device count must be pinned before jax initializes (same pattern
# as launch/dryrun.py), so peek at argv before the heavy imports.
_pre = argparse.ArgumentParser(add_help=False)
_pre.add_argument("--devices", type=int, default=None)
_pre.add_argument("--shards", type=int, default=2)
_pre_args, _ = _pre.parse_known_args()
_n_dev = (_pre_args.devices if _pre_args.devices is not None
          else _pre_args.shards)
if _n_dev and _n_dev > 1:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_n_dev}")

import jax
import numpy as np

from repro.core.params import params_for
from repro.data.synthetic import make_dataset
from repro.query.engine import QueryConfig, QueryEngine, QueryRequest
from repro.query.index import build_index


def _serve_waves(engine: QueryEngine, profiles, k: int) -> dict:
    """One cold + one warm wave through ``engine``; per-wave stats."""
    out = {}
    for tag in ("cold", "warm"):
        for rid, p in enumerate(profiles):
            engine.submit(QueryRequest(rid=rid, profile=p))
        stats = engine.run()
        recall = engine.recall_vs_brute_force(engine.done[-len(profiles):])
        out[tag] = {
            "qps": round(stats["qps"], 1),
            "p50_latency_ms": round(stats["p50_latency_s"] * 1e3, 2),
            "p95_latency_ms": round(stats["p95_latency_s"] * 1e3, 2),
            f"recall_at_{k}": round(recall, 4),
        }
    return out


def _warm_wave_capacities(engine: QueryEngine, profiles, hop_set=(None,)):
    """Compile the wave program for every pow-2 wave capacity × hop
    budget the open-loop run can hit (waves are padded to capacity
    buckets), so a mid-run compile doesn't pollute the latency
    measurement."""
    for hops in hop_set:
        n = 1
        while True:
            engine.query_batch(profiles[: min(n, len(profiles))],
                               hops=hops)
            if n >= len(profiles):  # final call warms the top bucket
                break
            n *= 2


def open_loop(engine: QueryEngine, profiles, rate_qps: float,
              budgets=None, seed: int = 0, timeout_s: float = 300.0) -> dict:
    """Poisson-arrival open-loop serving through ``engine.step()``.

    Requests are submitted at their arrival times (exponential
    inter-arrivals at ``rate_qps``) while the engine serves — so a
    request's latency includes the queueing it actually experiences
    behind in-flight work, which is where wave and continuous modes
    diverge. ``budgets`` (optional int[n]) gives each request its own
    hop budget: wave mode convoys a wave to its deepest member, while
    continuous mode frees each slot at its own budget.
    """
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_qps,
                                         size=len(profiles)))
    reqs = [QueryRequest(rid=i, profile=p,
                         hops=None if budgets is None else int(budgets[i]))
            for i, p in enumerate(profiles)]
    n_done0 = len(engine.done)
    n_steps = 0
    t0 = time.perf_counter()
    i = 0
    while len(engine.done) - n_done0 < len(reqs):
        now = time.perf_counter() - t0
        if now > timeout_s:
            raise RuntimeError(
                f"open_loop stalled: {len(engine.done) - n_done0}"
                f"/{len(reqs)} done after {timeout_s}s")
        while i < len(reqs) and arrivals[i] <= now:
            req = reqs[i]
            # Latency counts from the ARRIVAL time, not from when the
            # driver got around to enqueueing it — a request that landed
            # while a long wave was in flight has been waiting since its
            # arrival, and that queueing is the quantity under test.
            req.t_submit = t0 + arrivals[i]
            engine.queue.append(req)
            i += 1
        if engine.busy():
            engine.step()
            n_steps += 1
        elif i < len(reqs):  # idle: sleep to the next arrival
            time.sleep(max(min(arrivals[i] - now, 0.01), 0.0))
    dt = max(time.perf_counter() - t0, 1e-9)
    served = engine.done[n_done0:]
    lats = np.array([r.latency for r in served])
    return {
        "rate_qps": round(rate_qps, 1),
        "achieved_qps": round(len(served) / dt, 1),
        "steps": n_steps,
        "p50_latency_ms": round(float(np.percentile(lats, 50)) * 1e3, 2),
        "p95_latency_ms": round(float(np.percentile(lats, 95)) * 1e3, 2),
        "max_latency_ms": round(float(lats.max()) * 1e3, 2),
    }


def run_continuous(index, profiles, k: int, beam: int, hops: int,
                   slots: int, load: float = 0.85, deep_frac: float = 0.2,
                   seed: int = 0, shards: int = 1,
                   oversample: float = 1.25) -> dict:
    """Wave vs continuous under identical Poisson load + closed-loop rows.

    The open-loop workload is heterogeneous — ``deep_frac`` of the
    requests carry a 2× hop budget (refinement queries, the "slow
    descent" of the PR motivation). Wave batching convoys every wave
    containing a deep request to the deep budget; continuous serving
    frees each slot at its own budget, which is where the tail-latency
    gap comes from. ``shards > 1`` runs BOTH modes under the sharded
    placement (the sharded × continuous plan composition): batching is
    results-transparent for a fixed placement, so the closed-loop
    parity check below must hold bitwise — and the smoke gate fails if
    it drifts by even one bit.
    """
    place = dict(shards=shards, shard_oversample=oversample)
    cont = QueryEngine(index, QueryConfig(k=k, beam=beam, hops=hops,
                                          continuous=True, slots=slots,
                                          **place))
    closed = _serve_waves(cont, profiles, k)

    # A sustained arrival stream (2× the profile set) and a few
    # repetitions: a single short burst is a convoy lottery — backlog
    # needs time to build before the wave-mode tail shows.
    deep_hops = 2 * hops
    stream = profiles * 2
    reps = 3
    rng = np.random.default_rng(seed + 1)
    budgets = np.where(rng.random(len(stream)) < deep_frac,
                       deep_hops, hops)

    # Calibrate offered load against the wave engine's warm closed-loop
    # throughput on this mixed workload (one drain = one deep-budget
    # wave), then run below the knee so neither mode saturates outright.
    wave_ol = QueryEngine(index, QueryConfig(k=k, beam=beam, hops=hops,
                                             max_wave=len(stream),
                                             **place))
    _warm_wave_capacities(wave_ol, stream, hop_set=(hops, deep_hops))
    # Closed-loop parity vs wave on the SAME placement: batching must be
    # results-transparent, i.e. bitwise-equal (ids AND sims) per request.
    for rid, p in enumerate(profiles):
        wave_ol.submit(QueryRequest(rid=rid, profile=p))
    wave_ol.run()
    wave_closed_recall = wave_ol.recall_vs_brute_force()
    w_by = {r.rid: r for r in wave_ol.done}
    c_by = {r.rid: r for r in cont.done[-len(profiles):]}
    bitwise = all(np.array_equal(w_by[rid].ids, c_by[rid].ids)
                  and np.array_equal(w_by[rid].sims, c_by[rid].sims)
                  for rid in c_by)
    wave_ol.done.clear()
    for rid, p in enumerate(stream):
        wave_ol.submit(QueryRequest(rid=rid, profile=p,
                                    hops=int(budgets[rid])))
    mixed_qps = wave_ol.run()["qps"]
    wave_ol.done.clear()
    rate = max(load * mixed_qps, 1.0)

    cont_ol = QueryEngine(index, QueryConfig(k=k, beam=beam, hops=hops,
                                             continuous=True, slots=slots,
                                             **place))
    for rid, p in enumerate(stream[: 2 * slots]):
        cont_ol.submit(QueryRequest(rid=-1 - rid, profile=p))  # warm ticks
    cont_ol.run()
    cont_ol.done.clear()

    runs = {"wave": [], "continuous": []}
    for rep in range(reps):
        runs["wave"].append(open_loop(wave_ol, stream, rate,
                                      budgets=budgets, seed=seed + rep))
        runs["continuous"].append(open_loop(cont_ol, stream, rate,
                                            budgets=budgets,
                                            seed=seed + rep))

    def median_row(rows):
        out = {"rate_qps": rows[0]["rate_qps"]}
        for key in ("achieved_qps", "p50_latency_ms", "p95_latency_ms",
                    "max_latency_ms"):
            out[key] = round(float(np.median([r[key] for r in rows])), 2)
        out["p95_latency_ms_reps"] = [r["p95_latency_ms"] for r in rows]
        return out

    open_rows = {mode: median_row(rows) for mode, rows in runs.items()}
    wave_recall = wave_ol.recall_vs_brute_force()
    cont_recall = cont_ol.recall_vs_brute_force()
    return {
        "slots": slots,
        "shards": shards,
        "plan": cont.plan.describe(),
        "closed_loop": closed,
        "closed_loop_vs_wave": {
            "bitwise_equal": bitwise,
            "recall_delta": round(
                closed["warm"][f"recall_at_{k}"] - wave_closed_recall, 4),
        },
        "open_loop_workload": {
            "deep_frac": deep_frac,
            "hops": hops,
            "deep_hops": deep_hops,
            "load": load,
            "arrivals_per_rep": len(stream),
            "reps": reps,
            "mixed_wave_closed_loop_qps": round(mixed_qps, 1),
        },
        "open_loop": open_rows,
        "open_loop_recall": {
            "wave": round(wave_recall, 4),
            "continuous": round(cont_recall, 4),
            "delta": round(cont_recall - wave_recall, 4),
        },
        "p95_improvement": round(
            open_rows["wave"]["p95_latency_ms"]
            / max(open_rows["continuous"]["p95_latency_ms"], 1e-9), 3),
    }


def run_churn(index0, profiles, k: int, beam: int, hops: int,
              insert_pool, seed: int = 0, turnover: float = 0.2,
              rounds: int = 4, shards: int = 1) -> dict:
    """Sustained-churn recall trajectory, repair on vs off.

    Each round deletes ``turnover/rounds`` of the live rows and inserts
    replacements (true turnover: the live count is conserved), then
    serves the same fixed query wave through the scheduler loop — so
    lifecycle maintenance fires exactly as it would in production
    (between steps). The two arms see IDENTICAL mutation streams; the
    only difference is the repair cadence. Repair-off decays as deletes
    punch PAD holes into survivors' rows; repair-on re-links the
    churn-touched cohort and should hold recall near the no-churn
    baseline.
    """
    import copy

    m_round = max(1, int(turnover * index0.n_live / rounds))
    arms = {}
    baseline = None
    for arm, repair_every in (("repair_on", 1), ("repair_off", 0)):
        ix = copy.deepcopy(index0)
        eng = QueryEngine(ix, QueryConfig(
            k=k, beam=beam, hops=hops, max_wave=len(profiles),
            shards=shards, refresh_every=10**9,
            repair_every=repair_every))
        rng = np.random.default_rng(seed + 7)  # same stream both arms
        pool = iter(insert_pool)

        def wave_recall(eng=eng):
            for rid, p in enumerate(profiles):
                eng.submit(QueryRequest(rid=rid, profile=p))
            eng.run()
            return eng.recall_vs_brute_force(eng.done[-len(profiles):])

        if baseline is None:  # no-churn reference (arm-independent)
            baseline = round(wave_recall(), 4)
        else:
            wave_recall()  # warm this arm's programs identically
        trajectory = []
        for _ in range(rounds):
            alive = eng.index.alive_ids()
            for u in rng.choice(alive, size=min(m_round, len(alive) - 1),
                                replace=False):
                eng.remove_user(int(u))
            for _i in range(m_round):
                eng.insert(next(pool))
            trajectory.append(round(wave_recall(), 4))
        arms[arm] = {
            "recall_trajectory": trajectory,
            "final_recall": trajectory[-1],
            "lifecycle": eng.lifecycle.stats(),
        }
    return {
        "turnover": turnover,
        "rounds": rounds,
        "deletes_per_round": m_round,
        "no_churn_recall": baseline,
        **arms,
        "repair_recovery": round(
            arms["repair_on"]["final_recall"]
            - arms["repair_off"]["final_recall"], 4),
        "repair_vs_baseline": round(
            arms["repair_on"]["final_recall"] - baseline, 4),
    }


def descent_scoring_stats(index, profiles, k: int, beam: int, hops: int,
                          seeds_per_config: int = 16) -> dict:
    """Per-hop scored-candidate counts through the fused kernel on the
    same routed wave the serving rows answer: how many estimator lanes
    survive dedup-before-scoring vs the unfused ``beam·(kg+kr)``."""
    import jax.numpy as jnp

    from repro.kernels.descent_score import ops as ds_ops
    from repro.query.router import routed_queries
    from repro.query.search import descent_init

    qw, qc, seeds = (jnp.asarray(x) for x in
                     routed_queries(index, profiles, seeds_per_config))
    g, r = jnp.asarray(index.graph_ids), jnp.asarray(index.rev_ids)
    w, c = jnp.asarray(index.words), jnp.asarray(index.card)
    beam = max(beam, k)
    bi, bs = descent_init(w, c, qw, qc, seeds, beam=beam)
    per_hop = []
    for _ in range(hops):
        bi, bs, nsc = ds_ops.descent_hop(g, r, w, c, qw, qc, bi, bs,
                                         with_counts=True)
        per_hop.append(float(np.asarray(nsc).mean()))
    total = beam * (g.shape[1] + r.shape[1])
    return {
        "candidates_per_hop": total,
        "scored_per_hop_mean": [round(x, 1) for x in per_hop],
        "scored_fraction": round(float(np.mean(per_hop)) / total, 3),
    }


def run(dataset: str = "synth", scale: float = 0.2, n_queries: int = 256,
        k: int = 10, beam: int = 32, hops: int = 3, seed: int = 0,
        shards: int = 2, oversample: float = 1.25,
        continuous: bool = False, slots: int = 32,
        churn: bool = False) -> dict:
    if shards < 2:
        raise SystemExit("query_bench compares sharded vs single-device "
                         "serving; --shards must be >= 2")
    ds = make_dataset(dataset, scale=scale, seed=seed)
    params = params_for(dataset, k=k, b=max(64, ds.n_users // 16),
                        max_cluster=max(48, int(0.06 * ds.n_users)))
    t0 = time.perf_counter()
    index = build_index(ds, params)
    t_build = time.perf_counter() - t0

    qds = make_dataset(dataset, scale=scale, seed=seed + 1)
    n_q = min(n_queries, qds.n_users)
    profiles = [qds.profile(u) for u in range(n_q)]

    single = QueryEngine(index, QueryConfig(k=k, beam=beam, hops=hops,
                                            max_wave=n_queries))
    sharded = QueryEngine(index, QueryConfig(k=k, beam=beam, hops=hops,
                                             max_wave=n_queries,
                                             shards=shards,
                                             shard_oversample=oversample))
    # Fused descent-scoring kernel rows, same index and query set — the
    # acceptance bar is recall parity to ±0.000 (the kernel is bitwise
    # transparent), so these rows isolate pure serving-path overheads.
    single_kernel = QueryEngine(index, QueryConfig(
        k=k, beam=beam, hops=hops, max_wave=n_queries, kernel=True))
    sharded_kernel = QueryEngine(index, QueryConfig(
        k=k, beam=beam, hops=hops, max_wave=n_queries, shards=shards,
        shard_oversample=oversample, kernel=True))
    modes = {
        "single": _serve_waves(single, profiles, k),
        f"sharded_{shards}": _serve_waves(sharded, profiles, k),
        "single_kernel": _serve_waves(single_kernel, profiles, k),
        f"sharded_{shards}_kernel": _serve_waves(sharded_kernel, profiles, k),
    }
    scoring = descent_scoring_stats(index, profiles, k, beam, hops)
    sd = sharded.sharded_state()
    sharded_exec = "mesh" if sd is not None and sd.mesh is not None else "vmap"

    # Continuous-batching rows BEFORE the insert benchmark mutates the
    # shared index, so wave and continuous are measured on the same
    # index state and their recall numbers are directly comparable.
    cont = None
    cont_sharded = None
    if continuous:
        cont = run_continuous(index, profiles, k, beam, hops, slots,
                              seed=seed)
        # The sharded × continuous plan composition: same Poisson
        # open-loop protocol, per-shard slot arrays + release-time
        # cross-shard merge, gated bitwise against the sharded wave.
        cont_sharded = run_continuous(index, profiles, k, beam, hops,
                                      slots, seed=seed, shards=shards,
                                      oversample=oversample)

    # Sustained-churn trajectory BEFORE the insert benchmark, on private
    # deepcopies — the serving rows above and the churn arms must not
    # see each other's mutations.
    churn_rec = None
    if churn:
        # Replacement users come from an INDEPENDENT draw (seed+2) so the
        # inserts don't shadow the query distribution — the trajectory
        # should isolate graph damage, not ground-truth drift.
        ins_ds = make_dataset(dataset, scale=scale, seed=seed + 2)
        need = min(int(0.2 * index.n_live) + 8, ins_ds.n_users)
        pool = [ins_ds.profile(u) for u in range(need)]
        churn_rec = run_churn(index, profiles, k, beam, hops, pool,
                              seed=seed)

    # Online insertion through the amortized-growth path (single engine;
    # the index is shared, so the sharded engine reshards lazily).
    t0 = time.perf_counter()
    n_ins = min(64, qds.n_users - n_q)
    for m in range(n_ins):
        single.insert(qds.profile(n_q + m))
    t_ins = time.perf_counter() - t0

    sh = modes[f"sharded_{shards}"]["warm"]
    sg = modes["single"]["warm"]
    return {
        "dataset": ds.name,
        "n_users": ds.n_users,
        "n_queries": n_q,
        "k": k,
        "beam": beam,
        "hops": hops,
        "shards": shards,
        "shard_oversample": oversample,
        "sharded_execution": sharded_exec,
        "n_devices": jax.device_count(),
        "t_build_s": round(t_build, 2),
        "modes": modes,
        "inserts": n_ins,
        "inserts_per_s": round(n_ins / max(t_ins, 1e-9), 1),
        "cohort_refreshes": single.n_refreshes,
        "index_capacity": index.capacity,
        "descent_scoring": scoring,
        "kernel_vs_jnp": {
            "recall_delta": round(
                modes["single_kernel"]["warm"][f"recall_at_{k}"]
                - modes["single"]["warm"][f"recall_at_{k}"], 4),
            "sharded_recall_delta": round(
                modes[f"sharded_{shards}_kernel"]["warm"][f"recall_at_{k}"]
                - modes[f"sharded_{shards}"]["warm"][f"recall_at_{k}"], 4),
        },
        "sharded_vs_single": {
            "qps_ratio": round(sh["qps"] / max(sg["qps"], 1e-9), 3),
            "recall_delta": round(sh[f"recall_at_{k}"]
                                  - sg[f"recall_at_{k}"], 4),
        },
        **({"continuous": cont} if cont is not None else {}),
        **({f"sharded_{shards}_continuous": cont_sharded}
           if cont_sharded is not None else {}),
        **({"churn": churn_rec} if churn_rec is not None else {}),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="synth")
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--beam", type=int, default=32)
    ap.add_argument("--hops", type=int, default=3)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--oversample", type=float, default=1.25,
                    help="sharded fleet frontier vs single-device beam")
    ap.add_argument("--devices", type=int, default=None,
                    help="emulated host devices (default: --shards; 0=off)")
    ap.add_argument("--continuous", action="store_true",
                    help="add wave-vs-continuous closed/open-loop rows")
    ap.add_argument("--slots", type=int, default=32,
                    help="continuous-mode in-flight slot capacity")
    ap.add_argument("--churn", action="store_true",
                    help="add sustained-churn recall-trajectory rows "
                         "(repair on vs off under 20%% turnover)")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run; exit 1 on sharded regression")
    ap.add_argument("--out", default="BENCH_query.json")
    args = ap.parse_args()

    if args.smoke:
        args.scale, args.queries = min(args.scale, 0.1), min(args.queries, 64)
        args.slots = min(args.slots, 16)
    rec = run(args.dataset, args.scale, args.queries, args.k, args.beam,
              args.hops, shards=args.shards, oversample=args.oversample,
              continuous=args.continuous, slots=args.slots,
              churn=args.churn)
    Path(args.out).write_text(json.dumps(rec, indent=2))
    print(json.dumps(rec, indent=2))
    print(f"[query_bench] wrote {args.out}")

    if args.smoke:
        ratio = rec["sharded_vs_single"]["qps_ratio"]
        delta = rec["sharded_vs_single"]["recall_delta"]
        # CI floor: sharded must not collapse (generous margins — CI
        # machines are noisy; the committed BENCH_query.json carries the
        # quiet-machine numbers).
        if ratio < 0.5 or delta < -0.05:
            print(f"[query_bench] FAIL sharded regression: qps_ratio="
                  f"{ratio} recall_delta={delta}", file=sys.stderr)
            sys.exit(1)
        print(f"[query_bench] smoke OK: qps_ratio={ratio} "
              f"recall_delta={delta}")
        # The fused kernel is bitwise transparent: recall must match the
        # jnp rows EXACTLY (±0.000), and dedup-before-scoring must have
        # removed estimator work.
        kd = rec["kernel_vs_jnp"]
        frac = rec["descent_scoring"]["scored_fraction"]
        if kd["recall_delta"] != 0.0 or kd["sharded_recall_delta"] != 0.0:
            print(f"[query_bench] FAIL kernel recall drift: {kd}",
                  file=sys.stderr)
            sys.exit(1)
        if not frac < 1.0:
            print(f"[query_bench] FAIL kernel scored no fewer lanes: "
                  f"{rec['descent_scoring']}", file=sys.stderr)
            sys.exit(1)
        print(f"[query_bench] kernel smoke OK: recall_delta=0.0 "
              f"scored_fraction={frac}")
        if args.continuous:
            # Streaming admission must keep result quality: recall parity
            # with waves (identical descent ⇒ tight margin even on noisy
            # CI) and full completion of the open-loop run.
            cd = rec["continuous"]["open_loop_recall"]["delta"]
            if abs(cd) > 0.005:
                print(f"[query_bench] FAIL continuous recall drift: "
                      f"delta={cd}", file=sys.stderr)
                sys.exit(1)
            print(f"[query_bench] continuous smoke OK: recall_delta={cd} "
                  f"p95_improvement="
                  f"{rec['continuous']['p95_improvement']}")
            # Sharded × continuous composition: batching is results-
            # transparent under a fixed placement, so closed-loop results
            # must equal the sharded wave BITWISE (recall delta ±0.000).
            sc = rec[f"sharded_{args.shards}_continuous"]
            scw = sc["closed_loop_vs_wave"]
            if not scw["bitwise_equal"] or scw["recall_delta"] != 0.0:
                print(f"[query_bench] FAIL sharded-continuous drift vs "
                      f"sharded wave: {scw}", file=sys.stderr)
                sys.exit(1)
            scd = sc["open_loop_recall"]["delta"]
            if abs(scd) > 0.005:
                print(f"[query_bench] FAIL sharded-continuous open-loop "
                      f"recall drift: delta={scd}", file=sys.stderr)
                sys.exit(1)
            print(f"[query_bench] sharded-continuous smoke OK: "
                  f"closed-loop bitwise, open-loop recall_delta={scd}")
        if args.churn:
            # Under sustained turnover the repair pass must hold recall
            # near the no-churn baseline while repair-off is the decayed
            # arm (CI margins are generous; the committed
            # BENCH_query.json carries the quiet-machine trajectory).
            ch = rec["churn"]
            if ch["repair_vs_baseline"] < -0.03:
                print(f"[query_bench] FAIL churn repair did not hold "
                      f"recall: {ch['repair_vs_baseline']} vs baseline "
                      f"{ch['no_churn_recall']}", file=sys.stderr)
                sys.exit(1)
            # At smoke scale the two arms sit within noise of each other;
            # the gate only trips when repair actively HURTS recall.
            if ch["repair_recovery"] < -0.01:
                print(f"[query_bench] FAIL repair-on recall below "
                      f"repair-off: {ch['repair_recovery']}",
                      file=sys.stderr)
                sys.exit(1)
            print(f"[query_bench] churn smoke OK: repair_vs_baseline="
                  f"{ch['repair_vs_baseline']} recovery="
                  f"{ch['repair_recovery']}")


if __name__ == "__main__":
    main()
