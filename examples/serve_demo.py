"""Serve a small model with batched requests, in wave mode (queue →
prefill wave → batched decode) or continuous mode (slot-scheduled
streaming admission, ``--continuous``), with throughput/latency stats.

    PYTHONPATH=src python examples/serve_demo.py [--continuous]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models.config import scaled_down
from repro.models.model import init_params
from repro.serve.engine import Engine, Request, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--continuous", action="store_true",
                    help="slot-scheduled streaming admission instead of "
                         "closed waves (identical token streams)")
    args = ap.parse_args()

    cfg = scaled_down(get_config("llama3_2-1b"))
    params = init_params(jax.random.key(0), cfg)
    engine = Engine(params, cfg, ServeConfig(max_batch=4, max_prompt=32,
                                             max_new=16,
                                             continuous=args.continuous))
    rng = np.random.default_rng(0)
    for rid in range(10):
        plen = int(rng.integers(4, 32))
        engine.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new=int(rng.integers(4, 16))))
    stats = engine.run()
    unit = "ticks" if args.continuous else "waves"
    print("requests:", stats["requests"], f"{unit}:", stats["waves"],
          "decode steps:", stats["decode_steps"])
    print(f"throughput: {stats['tokens_per_s']:.1f} tok/s "
          f"({stats['mode']} greedy decode, CPU)")
    print(f"latency: mean {stats['mean_latency_s']:.2f}s "
          f"p95 {stats['p95_latency_s']:.2f}s")
    for r in engine.done[:3]:
        print(f"  req {r.rid}: {len(r.output)} tokens -> "
              f"{r.output[:8].tolist()}...")


if __name__ == "__main__":
    main()
