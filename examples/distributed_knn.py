"""Distributed C² on an emulated 8-device mesh: shard_map Step 2 with LPT
cluster scheduling, then verify against the single-device pipeline.

(XLA_FLAGS must be set before jax import — run this file directly.)

    PYTHONPATH=src python examples/distributed_knn.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.distributed import distributed_c2  # noqa: E402
from repro.core.params import C2Params  # noqa: E402
from repro.core.pipeline import cluster_and_conquer  # noqa: E402
from repro.data.synthetic import make_dataset  # noqa: E402
from repro.sketch.goldfinger import fingerprint_dataset  # noqa: E402


def main():
    ds = make_dataset("ml1M", scale=0.15, seed=7)
    gf = fingerprint_dataset(ds)
    p = C2Params(k=10, b=256, t=4, max_cluster=120)

    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    g_dist, stats = distributed_c2(ds, p, mesh, gf=gf)
    g_single, _ = cluster_and_conquer(ds, p, gf=gf)

    same = np.array_equal(g_dist.ids, g_single.ids)
    print(f"devices:        {stats['n_devices']}")
    print(f"clusters:       {stats['n_clusters']} "
          f"(LPT imbalance {stats['lpt_imbalance']:.3f})")
    print(f"matches single-device graph: {same}")
    assert same


if __name__ == "__main__":
    main()
