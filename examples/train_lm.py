"""End-to-end driver: train a ~100M-param llama-style LM for a few hundred
steps on CPU with checkpointing and C² locality-aware data ordering.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.launch import train as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    # ~100M-param reduction of llama3.2-1b (same family/blocks).
    T.main(["--arch", "llama3_2-1b", "--smoke",
            "--steps", str(args.steps),
            "--batch", "8", "--seq", "256",
            "--data-order", "c2",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50"])


if __name__ == "__main__":
    main()
