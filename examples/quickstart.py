"""Quickstart: build an approximate KNN graph with Cluster-and-Conquer.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

from repro.core.params import C2Params
from repro.core.pipeline import cluster_and_conquer
from repro.data.synthetic import make_dataset
from repro.eval.metrics import quality
from repro.knn.brute_force import brute_force_knn, n_similarities
from repro.sketch.goldfinger import fingerprint_dataset


def main():
    # A MovieLens-1M-statistics dataset at 30% user scale (offline container).
    ds = make_dataset("ml1M", scale=0.3, seed=0)
    print(f"dataset: {ds.n_users} users × {ds.n_items} items, "
          f"{ds.nnz} ratings ({100 * ds.density:.2f}% dense)")

    gf = fingerprint_dataset(ds)          # 1024-bit GoldFinger sketches
    t0 = time.perf_counter()
    exact = brute_force_knn(gf, k=10)     # the expensive reference
    t_bf = time.perf_counter() - t0

    params = C2Params(k=10, b=256, t=8, max_cluster=120)
    t0 = time.perf_counter()
    graph, stats = cluster_and_conquer(ds, params, gf=gf)
    t_c2 = time.perf_counter() - t0

    print(f"brute force: {t_bf:.2f}s ({n_similarities(ds.n_users):,} sims)")
    print(f"C²:          {t_c2:.2f}s ({stats.n_sims:,} sims, "
          f"{stats.n_clusters} clusters)")
    print(f"quality:     {quality(ds, graph, exact):.4f}  "
          f"(1.0 = exact graph)")
    print(f"sim budget:  ×{n_similarities(ds.n_users) / stats.n_sims:.1f} "
          f"fewer similarity computations")


if __name__ == "__main__":
    main()
