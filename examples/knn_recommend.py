"""End-to-end recommendation (paper §V-B): C² KNN graph → user-based CF →
recall against held-out items, vs the exact graph.

    PYTHONPATH=src python examples/knn_recommend.py
"""
from repro.core.params import C2Params
from repro.core.pipeline import cluster_and_conquer
from repro.data.synthetic import make_dataset, train_test_split
from repro.eval.metrics import recall, recommend
from repro.knn.brute_force import brute_force_knn
from repro.sketch.goldfinger import fingerprint_dataset


def main():
    ds = make_dataset("ml1M", scale=0.2, seed=1)
    train, test_rows = train_test_split(ds, test_frac=0.2, seed=1)
    gf = fingerprint_dataset(train)

    exact = brute_force_knn(gf, k=10)
    graph, _ = cluster_and_conquer(
        train, C2Params(k=10, b=256, t=8, max_cluster=120), gf=gf)

    r_exact = recall(recommend(train, exact, n_rec=30), test_rows)
    r_c2 = recall(recommend(train, graph, n_rec=30), test_rows)
    print(f"recall@30 exact graph: {r_exact:.3f}")
    print(f"recall@30 C² graph:    {r_c2:.3f}  (Δ {r_c2 - r_exact:+.3f})")


if __name__ == "__main__":
    main()
