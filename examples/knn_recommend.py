"""End-to-end recommendation through the serving stack (paper §V-B):
build a C² index, serve every user's profile through the QueryEngine to
get its neighbors, then user-based CF recall against held-out items —
compared with the exact brute-force graph.

This is the build → serve path a production deployment takes: the
recommender never touches the raw graph, only the query engine.

    PYTHONPATH=src python examples/knn_recommend.py

``--shards`` / ``--continuous`` / ``--kernel`` select the serving plan
(placement × batching × scorer, repro/query/plan.py) — the same axes
the benchmarks measure, so the example can exercise any plan the
serving stack supports. Recommendation quality is plan-independent for
a fixed placement (batching and scorer are results-transparent).

The demo closes with the lifecycle loop (repro/lifecycle/): a user
deletion (GDPR-style takedown) and a profile update served online —
the deleted user disappears from every re-queried neighborhood and the
updated user's neighbors shift to its new taste, with no rebuild.
"""
import argparse

import numpy as np

from repro.core.params import C2Params
from repro.data.synthetic import make_dataset, train_test_split
from repro.eval.metrics import recall, recommend
from repro.knn.brute_force import brute_force_knn
from repro.query.engine import QueryConfig, QueryEngine, QueryRequest
from repro.query.index import build_index
from repro.sketch.goldfinger import fingerprint_dataset
from repro.types import KNNGraph


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=1,
                    help="serve across this many LPT cluster shards")
    ap.add_argument("--continuous", action="store_true",
                    help="slot-based continuous batching")
    ap.add_argument("--slots", type=int, default=32,
                    help="in-flight slot capacity in continuous mode")
    ap.add_argument("--kernel", action="store_true",
                    help="fused Pallas descent-scoring hop")
    args = ap.parse_args(argv)

    ds = make_dataset("ml1M", scale=0.2, seed=1)
    train, test_rows = train_test_split(ds, test_frac=0.2, seed=1)
    gf = fingerprint_dataset(train)

    # Build the servable index once (Step 1–3 + routing tables).
    params = C2Params(k=10, b=256, t=8, max_cluster=120)
    index = build_index(train, params, gf=gf)
    engine = QueryEngine(index, QueryConfig(
        k=11, beam=32, hops=3, shards=args.shards,
        continuous=args.continuous, slots=args.slots, kernel=args.kernel))
    print(f"serving plan: {engine.plan.describe()}")

    # Serve every user's own profile; mask the self-match to recover its
    # neighborhood, exactly what a live recommender would do.
    for u in range(train.n_users):
        engine.submit(QueryRequest(rid=u, profile=train.profile(u)))
    stats = engine.run()
    order = np.argsort([r.rid for r in engine.done])
    ids = np.stack([r.ids for r in engine.done])[order]
    sims = np.stack([r.sims for r in engine.done])[order]
    # Stable-sort the self-match (if any) to the end of each row, then
    # drop the last slot — non-self neighbors keep their sim-desc order.
    self_mask = ids == np.arange(train.n_users)[:, None]
    keep = np.argsort(self_mask, axis=1, kind="stable")[:, : ids.shape[1] - 1]
    served = KNNGraph(ids=np.take_along_axis(ids, keep, axis=1),
                      sims=np.take_along_axis(sims, keep, axis=1))

    exact = brute_force_knn(gf, k=10)
    r_exact = recall(recommend(train, exact, n_rec=30), test_rows)
    r_served = recall(recommend(train, served, n_rec=30), test_rows)
    print(f"served {stats['requests']} queries at {stats['qps']:.0f} QPS "
          f"(p95 {stats['p95_latency_s'] * 1e3:.1f}ms)")
    print(f"recall@30 exact graph:   {r_exact:.3f}")
    print(f"recall@30 served (C²):   {r_served:.3f}  "
          f"(Δ {r_served - r_exact:+.3f})")

    # -- lifecycle: delete + update, then re-serve --------------------
    # Takedown: the most-recommended user must vanish from results.
    gone = int(np.bincount(served.ids.ravel(),
                           minlength=train.n_users).argmax())
    watchers = np.flatnonzero((served.ids == gone).any(axis=1))
    engine.remove_user(gone)
    # Taste change: re-link one of the watchers onto user 0's profile.
    moved = int(watchers[0]) if len(watchers) else 1
    engine.update_user(moved, train.profile(0))
    engine.lifecycle.repair()  # heal the delete-damaged rows now

    # Re-query the watchers' own profiles plus the NEW taste (user 0's
    # profile): the moved user must now surface as one of its neighbors.
    probes = [train.profile(int(u)) for u in watchers[:16]]
    probes.append(train.profile(0))
    re_ids, _ = engine.query_batch(probes, k=11)
    assert not (re_ids == gone).any(), "deleted user still served"
    print(f"lifecycle: removed user {gone} (was in {len(watchers)} "
          f"neighborhoods — now in 0 of {len(probes)} re-queries), "
          f"updated user {moved} "
          f"({'now' if moved in re_ids[-1] else 'NOT'} a neighbor of its "
          f"new taste), stats {engine.lifecycle.stats()}")


if __name__ == "__main__":
    main()
